//! Serving demo: load a pruned checkpoint into the sparse inference
//! engine and serve a batch of generation requests, reporting per-request
//! latency, aggregate throughput and weight memory vs the dense backend
//! (the deployment story of paper §5.3 / Table 1).
//!
//! Run: `cargo run --release --example serve_sparse`
//! (pretrains + prunes a model on the fly if no checkpoint is given;
//!  pass `-- --ckpt path.bin` to serve an existing one)
//!
//! `-- --batch N --threads N` switches to the batched engine: requests
//! are served N at a time with per-slot KV caches and slot retirement,
//! sharded across worker threads. Outputs are bit-identical to the
//! one-at-a-time path (same per-request seeds), only faster.
//!
//! `-- --max-slots N` switches to the queue-driven continuous-batching
//! scheduler: requests with ragged token budgets arrive Poisson-ishly
//! (seeded, deterministic) and are admitted into freed slots
//! mid-decode, with KV buffers recycled through the scheduler's
//! `KvPool`. Per-request outputs stay bit-identical to the
//! one-at-a-time path; a static-chunked run of the same stream is
//! reported alongside for the throughput comparison.
//!
//! `-- --shard-workers M` additionally splits every layer's linears
//! into M byte-balanced row-band shards executed on a persistent
//! per-worker pool (slot × band parallelism; still bit-identical).
//!
//! `-- --prefill-chunk C` sets the prompt window of the chunked
//! prefill pass (default 16; every value is bit-identical — prompts
//! just share one weight walk per window and skip the head projection
//! until their final position).
//!
//! `-- --prefix-cache {on,off}` toggles the scheduler's shared-prefix
//! KV cache (default on): admitted requests whose prompt extends a
//! previously served prefix copy the cached KV rows and prefill only
//! their suffix. Outputs stay bit-identical either way; the scheduler
//! line reports the hit count.
//!
//! `-- --quant {none,int8,int4}` serves quantized sparse payloads
//! (`CsrQ`/`MackoQ`, the Elsa-L path): dequantization is fused into
//! the tiled kernels, so the quantized engines ride the same
//! scheduler/pool/prefill machinery. The dense backend is skipped
//! when a quant mode is active (quantization targets the sparse
//! serving formats); token streams are reproducible within a mode but
//! tolerance-bounded vs f32, so per-mode throughput and weight bytes
//! are the cells to compare.
//!
//! `-- --nm {off,2:4,4:8}` projects the pruned checkpoint onto an N:M
//! pattern (`nm_project`, magnitude per group) and serves it through
//! the branch-free `NmSparse` kernels. Dense is skipped like in quant
//! mode, and the tokens differ from the unstructured run (projection
//! changes the weights) but stay deterministic per seed.
//!
//! `-- --pin-workers {on,off}` (default off) pins the shard pool's
//! lanes to cores — a best-effort placement hint, bit-identical
//! output either way. `-- --kernel-path {scalar,unrolled}` forces the
//! kernel traversal (default unrolled; also bit-identical).

use std::path::Path;

use anyhow::Result;
use elsa::cli::Args;
use elsa::coordinator::elsa::{prune_elsa, ElsaOptions};
use elsa::coordinator::pretrain::{pretrain_cached, PretrainOptions};
use elsa::data::{Dataset, Grammar};
use elsa::infer::scheduler::{pin_workers_flag, prefix_cache_flag,
                             ragged_budgets, serve_static_chunks,
                             Request, RequestQueue, SchedOptions,
                             Scheduler};
use elsa::infer::{Backend, BatchOptions, Engine};
use elsa::model::checkpoint::Checkpoint;
use elsa::model::Params;
use elsa::runtime::Runtime;
use elsa::sparse::{nm_project, KernelPath, NmMode, QuantMode};
use elsa::tensor::Matrix;
use elsa::util::{human_bytes, stats::Summary};

/// Project every prunable linear onto the requested N:M pattern
/// (magnitude top-N per group) so the checkpoint passes `NmWeights`
/// verification at engine build.
fn project_nm(p: &Params, nm: NmMode) -> Params {
    let mut q = p.clone();
    for seg in q.cfg.segments.clone() {
        if seg.prunable && seg.is_matrix() {
            let w = Matrix::from_vec(
                seg.shape[0], seg.shape[1],
                q.flat[seg.offset..seg.end()].to_vec());
            let proj = nm_project(&w, nm.n(), nm.m());
            q.flat[seg.offset..seg.end()].copy_from_slice(&proj.data);
        }
    }
    q
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut full = vec!["serve".to_string()];
    full.extend(argv);
    let args = Args::parse(&full)?;

    let rt = Runtime::load(Path::new("artifacts"))?;
    let (cfg, params) = match args.get("ckpt") {
        Some(path) => {
            let ck = Checkpoint::load(Path::new(path))?;
            let cfg = rt.manifest.config(&ck.config)?.clone();
            let p = ck.get("params")?.clone();
            (cfg, p)
        }
        None => {
            let cfg = rt.manifest.config("tiny")?.clone();
            let ds = Dataset::standard("synth-c4", cfg.vocab);
            println!("no --ckpt given: pretraining + pruning tiny @ 90%");
            let dense = pretrain_cached(&rt, &cfg, &ds.train,
                                        &PretrainOptions::new(800),
                                        Path::new("checkpoints"))?;
            let (p, _) = prune_elsa(&rt, &cfg, &ds.train, &dense,
                                    &ElsaOptions::new(0.9, 250))?;
            (cfg, p)
        }
    };
    let params = Params::new(&cfg, params);
    println!("model {} | sparsity {:.2}%", cfg.name,
             100.0 * params.sparsity());

    let g = Grammar::named("synth-c4", cfg.vocab);
    let n_requests = args.usize_or("requests", 16)?;
    let batch = args.usize_or("batch", 1)?.max(1);
    let threads = args.usize_or("threads", 1)?;
    let shard_workers = args.usize_or("shard-workers", 1)?;
    let max_slots = args.usize_or("max-slots", 0)?;
    let prefill_chunk = args
        .usize_or("prefill-chunk", elsa::infer::DEFAULT_PREFILL_CHUNK)?
        .max(1);
    let prefix_cache = prefix_cache_flag(&args)?;
    let pin_workers = pin_workers_flag(&args)?;
    let quant = QuantMode::parse(&args.str_or("quant", "none"))?;
    let nm = NmMode::parse(&args.str_or("nm", "off"))?;
    let kernel_path = match args.get("kernel-path") {
        Some(p) => Some(KernelPath::parse(p)?),
        None => None,
    };
    // quantization / N:M target the sparse serving formats; dense is
    // only a meaningful baseline in f32 unstructured mode
    let backends: &[Backend] =
        if quant == QuantMode::None && !nm.is_on() {
            &[Backend::Dense, Backend::Csr, Backend::Macko]
        } else {
            if quant != QuantMode::None {
                println!("quant {} (dense backend skipped)",
                         quant.label());
            }
            if nm.is_on() {
                println!("nm {} (dense backend skipped)", nm.label());
            }
            &[Backend::Csr, Backend::Macko]
        };
    // an unstructured pruned checkpoint will not satisfy N:M — project
    // it once up front so every backend serves the same weights
    let params = if nm.is_on() {
        project_nm(&params, nm)
    } else {
        params
    };
    let prompt_len = 8;
    let n_new = cfg.seq_len - prompt_len;

    if max_slots > 0 {
        // queue-driven continuous batching: ragged budgets + seeded
        // Poisson-ish arrivals, admission into freed slots mid-decode
        let gap = args.f64_or("arrival-gap", 2.0)?;
        let budgets = ragged_budgets(n_new, n_requests, 5);
        let reqs: Vec<Request> = (0..n_requests)
            .map(|r| Request {
                id: r as u64,
                prompt: g.generate(prompt_len, r as u64),
                n_new: budgets[r],
                seed: r as u64,
                deadline: None,
            })
            .collect();
        let sopts = SchedOptions {
            max_slots,
            temperature: 0.8,
            threads,
            shard_workers,
            prefix_cache,
            pin_workers,
        };
        for &backend in backends {
            let mut engine =
                Engine::build_full(&params, backend, quant, nm)?;
            if let Some(p) = kernel_path {
                engine.kernel_path = p;
            }
            engine.prefill_chunk = prefill_chunk;
            // warmup + static reference on the identical stream
            serve_static_chunks(&engine, &reqs, &sopts);
            let (_, st) = serve_static_chunks(&engine, &reqs, &sopts);
            let queue = RequestQueue::with_poisson_arrivals(
                reqs.clone(), gap, 11);
            let sched = Scheduler::new(&engine, sopts.clone());
            let (finished, sc) = sched.run(queue);
            assert_eq!(finished.len(), n_requests);
            println!(
                "{:>6}: {:4} reqs ({max_slots} slots, {threads} thr, \
                 {shard_workers} bands) | \
                 sched {:8.1} tok/s | p50 {:7.2} ms | p95 {:7.2} ms | \
                 static {:8.1} tok/s | x{:.2} | kv reuse {}/{} | \
                 prefix hits {} (saved {} tok)",
                format!("{backend:?}"), n_requests,
                sc.tokens_per_second, sc.p50_latency_ms,
                sc.p95_latency_ms, st.tokens_per_second,
                sc.tokens_per_second / st.tokens_per_second.max(1e-9),
                sc.kv_reused, sc.kv_reused + sc.kv_allocated,
                sc.prefix_hits, sc.prefix_tokens_saved);
        }
        return Ok(());
    }

    for &backend in backends {
        let mut engine = Engine::build_full(&params, backend, quant, nm)?;
        if let Some(p) = kernel_path {
            engine.kernel_path = p;
        }
        engine.prefill_chunk = prefill_chunk;
        // warmup
        engine.generate(&g.generate(prompt_len, 0), n_new, 0.8, 0);
        let mut lat = Summary::new();
        let t0 = std::time::Instant::now();
        let mut total_tokens = 0usize;
        if batch <= 1 {
            // one request at a time (the original microbenchmark loop)
            for r in 0..n_requests {
                let prompt = g.generate(prompt_len, r as u64);
                let (_, stats) = engine.generate(&prompt, n_new, 0.8,
                                                 r as u64);
                lat.push(stats.decode_seconds * 1e3);
                total_tokens += stats.tokens_generated;
            }
        } else {
            // batched serving: groups of `batch` slots, each slot
            // seeded like its sequential twin so outputs match
            let mut r = 0usize;
            while r < n_requests {
                let n = batch.min(n_requests - r);
                let prompts: Vec<Vec<u32>> = (r..r + n)
                    .map(|i| g.generate(prompt_len, i as u64))
                    .collect();
                let opts = BatchOptions {
                    n_new, temperature: 0.8, seed: r as u64, threads,
                    shard_workers, prefix_cache, pin_workers,
                };
                let (_, stats) = engine.generate_batch(&prompts, &opts);
                // per-batch decode wall, amortized per request
                lat.push(stats.decode_seconds * 1e3 / n as f64);
                total_tokens += stats.tokens_generated;
                r += n;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:>6}: {:4} reqs (batch {batch}, {threads} thr) | \
             p50 {:7.2} ms | p95 {:7.2} ms | {:8.1} tok/s | weights {}",
            format!("{backend:?}"), n_requests, lat.median(),
            lat.percentile(95.0), total_tokens as f64 / wall,
            human_bytes(engine.mem_bytes()));
    }
    Ok(())
}

//! Quickstart: the minimal end-to-end ELSA flow.
//!
//!   1. load the AOT artifacts (run `make artifacts` once first),
//!   2. pretrain the `tiny` dense model briefly on the synthetic corpus,
//!   3. prune it to 80% sparsity with surrogate-free ADMM,
//!   4. report perplexity before/after and the achieved sparsity.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;

use anyhow::Result;
use elsa::coordinator::elsa::{prune_elsa, ElsaOptions};
use elsa::coordinator::eval_ppl;
use elsa::coordinator::pretrain::{pretrain, PretrainOptions};
use elsa::data::Dataset;
use elsa::model::Params;
use elsa::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let cfg = rt.manifest.config("tiny")?.clone();
    let ds = Dataset::standard("synth-c4", cfg.vocab);

    // 1-2: a quickly-pretrained dense model (the "LLM checkpoint")
    println!("pretraining tiny dense model (400 steps)...");
    let (dense, losses) =
        pretrain(&rt, &cfg, &ds.train, &PretrainOptions::new(400))?;
    println!("  loss {:.3} -> {:.3}", losses[0],
             losses[losses.len() - 1]);
    let dense_ppl = eval_ppl(&rt, &cfg, &dense, &ds.valid)?;
    println!("  dense validation ppl: {dense_ppl:.2}");

    // 3: ELSA at 80% sparsity
    println!("pruning to 80% with ELSA (200 ADMM x-steps, interval k=32)");
    let opts = ElsaOptions::new(0.80, 200);
    let (pruned, metrics) =
        prune_elsa(&rt, &cfg, &ds.train, &dense, &opts)?;

    // 4: report
    let sparse_ppl = eval_ppl(&rt, &cfg, &pruned, &ds.valid)?;
    let p = Params::new(&cfg, pruned);
    println!("  achieved sparsity: {:.2}%", 100.0 * p.sparsity());
    println!("  pruned validation ppl: {sparse_ppl:.2} \
              (dense was {dense_ppl:.2})");
    println!("  final primal residual ||x-z||/||x||: {:.2e}",
             metrics.residuals.last().map(|r| r.1).unwrap_or(f64::NAN));
    println!("done in {:.1}s of ADMM time", metrics.wall_seconds);
    Ok(())
}

//! Offline stub of the `xla` PJRT bindings (vendored).
//!
//! The native `xla_extension` closure is not in the offline vendor set,
//! so this crate provides just enough of the API surface for the
//! workspace to compile and for the non-runtime test suite to run:
//!
//!  - [`Literal`] is a real, functional host-side tensor value
//!    (`vec1`, `scalar`, `reshape`, `to_vec`, `get_first_element`,
//!    `to_tuple` all work),
//!  - [`PjRtClient::cpu`] succeeds (so manifest-only flows like the
//!    sparse serving CLI keep working), while the paths that genuinely
//!    need native XLA — [`HloModuleProto::from_text_file`],
//!    [`PjRtClient::compile`], [`PjRtLoadedExecutable::execute`] —
//!    return [`Error`] at runtime, reporting that the native backend
//!    is unavailable in this build.
//!
//! The runtime integration tests skip themselves when `artifacts/` is
//! absent, so a fresh checkout stays green; anything that actually
//! needs XLA execution fails loudly with a clear message instead of
//! failing to link.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "xla backend unavailable in this offline build (wanted: {what}); \
             rebuild against the native xla_extension closure to enable \
             PJRT execution"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn lit_from_slice(xs: &[Self]) -> Literal;
    fn lit_to_vec(lit: &Literal) -> Result<Vec<Self>>;
}

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side tensor value (rank tracked via `dims`).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl NativeType for f32 {
    fn lit_from_slice(xs: &[Self]) -> Literal {
        Literal { data: Data::F32(xs.to_vec()), dims: vec![xs.len() as i64] }
    }

    fn lit_to_vec(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".to_string())),
        }
    }
}

impl NativeType for i32 {
    fn lit_from_slice(xs: &[Self]) -> Literal {
        Literal { data: Data::I32(xs.to_vec()), dims: vec![xs.len() as i64] }
    }

    fn lit_to_vec(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".to_string())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(xs: &[T]) -> Literal {
        T::lit_from_slice(xs)
    }

    /// Rank-0 f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { data: Data::F32(vec![x]), dims: vec![] }
    }

    fn numel(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    /// Same data, new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.numel() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Flattened element vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::lit_to_vec(self)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::lit_to_vec(self)?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".to_string()))
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(t) => Ok(t),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }

    /// Build a tuple literal (used by tests of the stub itself).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let n = elems.len() as i64;
        Literal { data: Data::Tuple(elems), dims: vec![n] }
    }
}

/// Parsed HLO module (stub: construction always fails offline).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parse HLO text {path}")))
    }
}

/// A computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. The stub client constructs successfully so
/// manifest-only paths (checkpoint serving, the batched engine CLI,
/// experiment plumbing) stay alive; only `compile`/`execute` — the
/// points that genuinely need native XLA — fail.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-offline".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

/// Types accepted as positional arguments by `execute`.
pub trait ExecuteInput {}

impl ExecuteInput for Literal {}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: ExecuteInput>(&self, _args: &[T])
                                    -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_numel() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.to_vec::<i32>().unwrap().len(), 6);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_unpacks() {
        let t = Literal::tuple(vec![Literal::scalar(1.0),
                                    Literal::scalar(2.0)]);
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn pjrt_client_constructs_but_execution_paths_fail_loudly() {
        let client = PjRtClient::cpu().expect("stub client must build");
        assert_eq!(client.device_count(), 0);
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let comp = XlaComputation { _private: () };
        assert!(client.compile(&comp).is_err());
    }
}

//! Offline stand-in for the `anyhow` crate (vendored).
//!
//! The real crate is not in the offline vendor set, so this shim
//! provides the exact API subset the workspace uses: [`Result`],
//! [`Error`], the `anyhow!` / `bail!` / `ensure!` macros, and the
//! [`Context`] extension trait on `Result` and `Option`.
//!
//! Errors carry a plain message chain (no backtraces, no downcasting):
//! `{e}` prints the outermost message, `{e:#}` the full chain joined
//! with `": "` — matching anyhow's Display behaviour for the formats
//! the binaries actually print.

use std::fmt;

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error. The chain is stored innermost (root cause)
/// first; context frames are appended as they wrap the error.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame (what `Context::context` does).
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// Messages outermost-first, like `anyhow::Error::chain`.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }

    /// The root-cause message (innermost frame).
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, m) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
            }
            Ok(())
        } else {
            // outermost frame only, like anyhow
            write!(f, "{}", self.chain.last().expect("non-empty chain"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

/// Every std error converts into `Error` via `?` (mirrors anyhow's
/// blanket `From`; sound because `Error` itself is not a `std::error::
/// Error`, so the reflexive `From` impl cannot overlap).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // flatten the source chain into our message chain
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.insert(0, s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Conversion used by the `Context` impl so `.context(...)` works both
/// on `Result<T, E: std::error::Error>` and on `Result<T, Error>`.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Drop-in for `anyhow::Context`.
pub trait Context<T>: Sized {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.wrap("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: root");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 1));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 1");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            ensure!(x != 7);
            if x == 3 {
                bail!("three");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three");
        assert!(format!("{}", f(7).unwrap_err()).contains("x != 7"));
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}

"""Unit tests for the CI bench regression gate (pure stdlib).

Run: python3 -m unittest discover ci
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import compare_bench as cb  # noqa: E402


def scheduler_baseline():
    return {
        "tolerance": 0.15,
        "min_speedup_x": 0.9,
        "min_prefix_cached_uncached_ratio": 1.0,
        "sequential": {"tok_s": 50.0},
        "static": {"tok_s": 60.0},
        "continuous": {"tok_s": 80.0},
        "continuous_pooled": {"tok_s": 80.0},
        "prefix_cached": {"tok_s": 60.0},
    }


def scheduler_current(seq=100.0, stat=120.0, cont=150.0, pooled=150.0,
                      speedup=1.25, prefix_cached=160.0,
                      prefix_ratio=1.4):
    return {
        "sequential": {"tok_s": seq},
        "static": {"tok_s": stat, "p50_ms": 1.0, "p95_ms": 2.0},
        "continuous": {"tok_s": cont, "p50_ms": 1.0, "p95_ms": 2.0},
        "continuous_pooled": {"tok_s": pooled, "p50_ms": 1.0,
                              "p95_ms": 2.0},
        "prefix_cached": {"tok_s": prefix_cached, "p50_ms": 1.0,
                          "p95_ms": 2.0},
        "prefix_uncached_tok_s": prefix_cached / prefix_ratio,
        "prefix_cached_uncached_ratio": prefix_ratio,
        "prefix_hits": 23.0,
        "prefix_tokens_saved": 1104.0,
        "prefix_hit_rate": 0.96,
        "speedup_x": speedup,
    }


def kernels_baseline():
    return {
        "min_tiled_untiled_ratio": 0.95,
        "min_pooled_serial_ratio": 0.95,
        "min_chunked_pertoken_ratio": 1.0,
        "min_int8_f32_ratio": 1.0,
        "min_nm24_csr_ratio": 1.0,
        "min_unrolled_scalar_ratio": 1.0,
        "nm24_b1": {"tok_s": 30.0},
        "nm24_b8": {"tok_s": 30.0},
        "dense": {"tok_s": 25.0},
        "csr": {"tok_s": 40.0},
        "macko": {"tok_s": 40.0},
        "macko_pooled": {"tok_s": 40.0},
        "macko_prefill": {"tok_s": 50.0},
        "csr_int8": {"tok_s": 30.0},
        "macko_int4": {"tok_s": 30.0},
    }


def kernels_current(ratio=1.1, pooled_ratio=1.0, chunked_ratio=1.6,
                    dense=80.0, csr=200.0, macko=220.0,
                    macko_pooled=240.0, macko_prefill=300.0,
                    csr_int8=260.0, macko_int4=210.0,
                    int8_f32_ratio=1.4, nm24_csr_ratio=1.3,
                    unrolled_scalar_ratio=1.05, nm24_b1=190.0,
                    nm24_b8=230.0):
    return {
        "tiled_untiled_ratio": ratio,
        "pooled_serial_ratio": pooled_ratio,
        "chunked_pertoken_ratio": chunked_ratio,
        "int8_f32_ratio": int8_f32_ratio,
        "nm24_csr_ratio": nm24_csr_ratio,
        "unrolled_scalar_ratio": unrolled_scalar_ratio,
        "nm24_b1": {"tok_s": nm24_b1},
        "nm24_b8": {"tok_s": nm24_b8},
        "dense": {"tok_s": dense},
        "csr": {"tok_s": csr},
        "macko": {"tok_s": macko},
        "macko_pooled": {"tok_s": macko_pooled},
        "macko_prefill": {"tok_s": macko_prefill,
                          "pertoken_tok_s": macko_prefill / 1.6},
        "csr_int8": {"tok_s": csr_int8},
        "macko_int4": {"tok_s": macko_int4},
    }


def prune_baseline():
    return {
        "min_prune_parallel_serial_ratio": 1.0,
        "magnitude_w1": {"tok_s": 50000.0},
        "magnitude_par": {"tok_s": 50000.0},
        "sparsegpt_w1": {"tok_s": 5000.0},
        "sparsegpt_par": {"tok_s": 5000.0},
        "ladmm_w1": {"tok_s": 500.0},
        "ladmm_par": {"tok_s": 500.0},
    }


def prune_current(ratio=1.6, magnitude=4.0e6, sparsegpt=9.0e4,
                  ladmm=8.0e3):
    return {
        "prune_parallel_serial_ratio": ratio,
        "magnitude_w1": {"tok_s": magnitude},
        "magnitude_par": {"tok_s": magnitude * 1.2},
        "sparsegpt_w1": {"tok_s": sparsegpt},
        "sparsegpt_par": {"tok_s": sparsegpt * 1.7},
        "ladmm_w1": {"tok_s": ladmm},
        "ladmm_par": {"tok_s": ladmm * 1.8},
    }


class GateTests(unittest.TestCase):
    def test_passes_when_above_floors(self):
        _, failures = cb.gate(scheduler_current(), scheduler_baseline())
        self.assertEqual(failures, [])

    def test_detects_throughput_drop(self):
        # continuous collapses below (1 - 0.15) * 80
        cur = scheduler_current(cont=10.0)
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertEqual(len(failures), 1)
        self.assertIn("continuous", failures[0])

    def test_exact_floor_passes_but_just_below_fails(self):
        floor = 80.0 * 0.85
        _, failures = cb.gate(scheduler_current(cont=floor),
                              scheduler_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(scheduler_current(cont=floor - 0.01),
                              scheduler_baseline())
        self.assertEqual(len(failures), 1)

    def test_missing_gated_policy_fails(self):
        cur = scheduler_current()
        del cur["static"]
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertTrue(any("static" in f and "missing" in f
                            for f in failures))

    def test_speedup_gate(self):
        cur = scheduler_current(speedup=0.5)
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertTrue(any("speedup" in f for f in failures))
        # absent speedup_x counts as 0.0 -> also fails
        cur = scheduler_current()
        del cur["speedup_x"]
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertTrue(any("speedup" in f for f in failures))

    def test_speedup_not_gated_when_baseline_lacks_knob(self):
        base = scheduler_baseline()
        del base["min_speedup_x"]
        cur = scheduler_current(speedup=0.0)
        _, failures = cb.gate(cur, base)
        self.assertEqual(failures, [])

    def test_tiled_ratio_gate(self):
        _, failures = cb.gate(kernels_current(), kernels_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(kernels_current(ratio=0.5),
                              kernels_baseline())
        self.assertTrue(any("tiled_untiled_ratio" in f for f in failures))

    def test_pooled_serial_ratio_gate(self):
        # the generic min_<name>_ratio machinery: pooled dispatch at
        # shard-workers=1 regressing >5% vs serial must fail
        _, failures = cb.gate(kernels_current(pooled_ratio=0.96),
                              kernels_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(kernels_current(pooled_ratio=0.5),
                              kernels_baseline())
        self.assertTrue(any("pooled_serial_ratio" in f for f in failures))
        # an absent ratio metric counts as 0.0 -> fails, not skips
        cur = kernels_current()
        del cur["pooled_serial_ratio"]
        _, failures = cb.gate(cur, kernels_baseline())
        self.assertTrue(any("pooled_serial_ratio" in f for f in failures))

    def test_chunked_pertoken_ratio_gate(self):
        # chunked prefill must never lose to per-token prefill: the
        # 1.0 floor fails a ratio just below it and an absent metric
        _, failures = cb.gate(kernels_current(chunked_ratio=1.0),
                              kernels_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(kernels_current(chunked_ratio=0.99),
                              kernels_baseline())
        self.assertTrue(any("chunked_pertoken_ratio" in f
                            for f in failures))
        cur = kernels_current()
        del cur["chunked_pertoken_ratio"]
        _, failures = cb.gate(cur, kernels_baseline())
        self.assertTrue(any("chunked_pertoken_ratio" in f
                            for f in failures))

    def test_int8_f32_ratio_gate(self):
        # fused-dequant int8 must never lose to f32 at the
        # bandwidth-bound decode shape: 1.0 passes at exactly 1.0,
        # fails just below, and an absent metric counts as 0.0
        _, failures = cb.gate(kernels_current(int8_f32_ratio=1.0),
                              kernels_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(kernels_current(int8_f32_ratio=0.99),
                              kernels_baseline())
        self.assertTrue(any("int8_f32_ratio" in f for f in failures))
        cur = kernels_current()
        del cur["int8_f32_ratio"]
        _, failures = cb.gate(cur, kernels_baseline())
        self.assertTrue(any("int8_f32_ratio" in f for f in failures))

    def test_nm24_csr_ratio_gate(self):
        # the branch-free N:M matvec must never lose to unstructured
        # CSR on the same projected matrix: 1.0 passes at exactly 1.0,
        # fails just below, and an absent metric counts as 0.0
        _, failures = cb.gate(kernels_current(nm24_csr_ratio=1.0),
                              kernels_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(kernels_current(nm24_csr_ratio=0.99),
                              kernels_baseline())
        self.assertTrue(any("nm24_csr_ratio" in f for f in failures))
        cur = kernels_current()
        del cur["nm24_csr_ratio"]
        _, failures = cb.gate(cur, kernels_baseline())
        self.assertTrue(any("nm24_csr_ratio" in f for f in failures))

    def test_unrolled_scalar_ratio_gate(self):
        # the unrolled kernel path must never cost throughput vs
        # scalar (bit-identical by construction, so the only thing
        # left to regress is speed)
        _, failures = cb.gate(
            kernels_current(unrolled_scalar_ratio=1.0),
            kernels_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(
            kernels_current(unrolled_scalar_ratio=0.99),
            kernels_baseline())
        self.assertTrue(any("unrolled_scalar_ratio" in f
                            for f in failures))
        cur = kernels_current()
        del cur["unrolled_scalar_ratio"]
        _, failures = cb.gate(cur, kernels_baseline())
        self.assertTrue(any("unrolled_scalar_ratio" in f
                            for f in failures))

    def test_nm_cell_floors_gated_like_any_policy(self):
        # the N:M decode cells ride the ordinary tok_s floor
        # machinery: collapse and disappearance both fail
        _, failures = cb.gate(kernels_current(nm24_b1=1.0),
                              kernels_baseline())
        self.assertTrue(any("nm24_b1" in f for f in failures))
        cur = kernels_current()
        del cur["nm24_b8"]
        _, failures = cb.gate(cur, kernels_baseline())
        self.assertTrue(any("nm24_b8" in f and "missing" in f
                            for f in failures))

    def test_ratchet_covers_nm_cells_and_keeps_nm_knobs(self):
        out = cb.ratchet(kernels_current(), kernels_baseline())
        self.assertEqual(out["nm24_b1"]["tok_s"], 190.0)
        self.assertEqual(out["nm24_b8"]["tok_s"], 230.0)
        # the min_ knobs are policy, never ratcheted
        self.assertEqual(out["min_nm24_csr_ratio"], 1.0)
        self.assertEqual(out["min_unrolled_scalar_ratio"], 1.0)

    def test_quant_cell_floors_gated_like_any_policy(self):
        # the quantized decode cells ride the ordinary tok_s floor
        # machinery: collapse and disappearance both fail
        _, failures = cb.gate(kernels_current(), kernels_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(kernels_current(csr_int8=1.0),
                              kernels_baseline())
        self.assertTrue(any("csr_int8" in f for f in failures))
        cur = kernels_current()
        del cur["macko_int4"]
        _, failures = cb.gate(cur, kernels_baseline())
        self.assertTrue(any("macko_int4" in f and "missing" in f
                            for f in failures))

    def test_ratchet_covers_quant_cells_and_keeps_int8_knob(self):
        out = cb.ratchet(kernels_current(), kernels_baseline())
        self.assertEqual(out["csr_int8"]["tok_s"], 260.0)
        self.assertEqual(out["macko_int4"]["tok_s"], 210.0)
        # min_int8_f32_ratio is policy, never ratcheted
        self.assertEqual(out["min_int8_f32_ratio"], 1.0)

    def test_prefill_cell_floor_gated_like_any_policy(self):
        # the {backend}_prefill cells ride the ordinary tok_s floor
        # machinery; extra keys (pertoken_tok_s) are ignored by the gate
        _, failures = cb.gate(kernels_current(), kernels_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(kernels_current(macko_prefill=1.0),
                              kernels_baseline())
        self.assertTrue(any("macko_prefill" in f for f in failures))
        cur = kernels_current()
        del cur["macko_prefill"]
        _, failures = cb.gate(cur, kernels_baseline())
        self.assertTrue(any("macko_prefill" in f and "missing" in f
                            for f in failures))

    def test_ratchet_covers_prefill_cells_and_keeps_ratio_knob(self):
        out = cb.ratchet(kernels_current(), kernels_baseline())
        self.assertEqual(out["macko_prefill"]["tok_s"], 300.0)
        self.assertEqual(out["min_chunked_pertoken_ratio"], 1.0)

    def test_pooled_policy_floor_gated(self):
        cur = scheduler_current(pooled=1.0)
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertTrue(any("continuous_pooled" in f for f in failures))
        cur = scheduler_current()
        del cur["continuous_pooled"]
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertTrue(any("continuous_pooled" in f and "missing" in f
                            for f in failures))

    def test_prefix_cached_uncached_ratio_gate(self):
        # cached serving of the shared-prefix stream must never lose
        # to uncached: the 1.0 floor passes at exactly 1.0, fails just
        # below, and an absent metric counts as 0.0 -> fails
        _, failures = cb.gate(scheduler_current(prefix_ratio=1.0),
                              scheduler_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(scheduler_current(prefix_ratio=0.99),
                              scheduler_baseline())
        self.assertTrue(any("prefix_cached_uncached_ratio" in f
                            for f in failures))
        cur = scheduler_current()
        del cur["prefix_cached_uncached_ratio"]
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertTrue(any("prefix_cached_uncached_ratio" in f
                            for f in failures))

    def test_prefix_cached_policy_floor_gated(self):
        # the prefix_cached cell rides the ordinary tok_s floor
        # machinery; the informational flat keys are ignored
        cur = scheduler_current(prefix_cached=1.0, prefix_ratio=1.4)
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertTrue(any("prefix_cached:" in f for f in failures))
        cur = scheduler_current()
        del cur["prefix_cached"]
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertTrue(any("prefix_cached" in f and "missing" in f
                            for f in failures))

    def test_ratchet_covers_prefix_cell_and_keeps_ratio_knob(self):
        out = cb.ratchet(scheduler_current(), scheduler_baseline())
        self.assertEqual(out["prefix_cached"]["tok_s"], 160.0)
        self.assertEqual(out["min_prefix_cached_uncached_ratio"], 1.0)

    def test_prune_parallel_serial_ratio_gate(self):
        # pool-parallel pruning must never lose wall-clock to the
        # serial walk: 1.0 passes at exactly 1.0, fails just below,
        # and an absent metric counts as 0.0 -> fails
        _, failures = cb.gate(prune_current(ratio=1.0),
                              prune_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(prune_current(ratio=0.99),
                              prune_baseline())
        self.assertTrue(any("prune_parallel_serial_ratio" in f
                            for f in failures))
        cur = prune_current()
        del cur["prune_parallel_serial_ratio"]
        _, failures = cb.gate(cur, prune_baseline())
        self.assertTrue(any("prune_parallel_serial_ratio" in f
                            for f in failures))

    def test_prune_cell_floors_gated_like_any_policy(self):
        # the per-method weight-throughput cells ride the ordinary
        # tok_s floor machinery: collapse and disappearance both fail
        _, failures = cb.gate(prune_current(), prune_baseline())
        self.assertEqual(failures, [])
        _, failures = cb.gate(prune_current(ladmm=1.0),
                              prune_baseline())
        self.assertTrue(any("ladmm_w1" in f for f in failures))
        cur = prune_current()
        del cur["sparsegpt_par"]
        _, failures = cb.gate(cur, prune_baseline())
        self.assertTrue(any("sparsegpt_par" in f and "missing" in f
                            for f in failures))

    def test_ratchet_covers_prune_cells_and_keeps_ratio_knob(self):
        out = cb.ratchet(prune_current(), prune_baseline())
        self.assertEqual(out["magnitude_w1"]["tok_s"], 4.0e6)
        self.assertEqual(out["ladmm_par"]["tok_s"], 8.0e3 * 1.8)
        # the min_ knob is policy, never ratcheted
        self.assertEqual(out["min_prune_parallel_serial_ratio"], 1.0)

    def test_explicit_tolerance_overrides_baseline(self):
        # floor becomes 80 * (1 - 0.5) = 40 with the looser tolerance
        cur = scheduler_current(cont=45.0)
        _, failures = cb.gate(cur, scheduler_baseline())
        self.assertEqual(len(failures), 1)
        _, failures = cb.gate(cur, scheduler_baseline(), tolerance=0.5)
        self.assertEqual(failures, [])


class RatchetTests(unittest.TestCase):
    def test_ratchet_updates_floors_only(self):
        base = scheduler_baseline()
        out = cb.ratchet(scheduler_current(), base)
        self.assertEqual(out["continuous"]["tok_s"], 150.0)
        self.assertEqual(out["static"]["tok_s"], 120.0)
        self.assertEqual(out["sequential"]["tok_s"], 100.0)
        # policy knobs are untouched, and the input is not mutated
        self.assertEqual(out["tolerance"], 0.15)
        self.assertEqual(out["min_speedup_x"], 0.9)
        self.assertEqual(base["continuous"]["tok_s"], 80.0)

    def test_ratchet_keeps_floor_for_missing_policy(self):
        cur = scheduler_current()
        del cur["sequential"]
        out = cb.ratchet(cur, scheduler_baseline())
        self.assertEqual(out["sequential"]["tok_s"], 50.0)


class DiffTests(unittest.TestCase):
    """The non-blocking floor-drift summary (--diff)."""

    def test_reports_drift_for_floors_and_ratios(self):
        lines = cb.diff(kernels_current(), kernels_baseline())
        text = "\n".join(lines)
        # every floored policy and every ratio knob appears
        for metric in ("macko", "nm24_b1", "nm24_csr_ratio",
                       "unrolled_scalar_ratio"):
            self.assertIn(metric, text)
        # 220 vs a 40 floor is +450%: flagged as a ratchet candidate
        self.assertIn("ratchet candidate", text)

    def test_flags_below_floor_without_failing(self):
        # a collapsed cell is *reported*, but diff never returns
        # failures — blocking is the gate's job
        lines = cb.diff(kernels_current(macko=1.0, nm24_csr_ratio=0.5),
                        kernels_baseline())
        text = "\n".join(lines)
        self.assertIn("below gate floor", text)
        self.assertIn("2 below gate floor", text)

    def test_missing_metrics_reported_not_fatal(self):
        cur = kernels_current()
        del cur["nm24_b8"]
        del cur["unrolled_scalar_ratio"]
        text = "\n".join(cb.diff(cur, kernels_baseline()))
        self.assertIn("missing", text)


class MainTests(unittest.TestCase):
    """End-to-end through main(): files on disk, exit codes, stdout."""

    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)

    def write(self, name, doc):
        path = os.path.join(self.dir.name, name)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def run_main(self, argv):
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            code = cb.main(argv)
        return code, out.getvalue()

    def full_baseline(self):
        doc = scheduler_baseline()
        doc["kernels"] = kernels_baseline()
        doc["prune"] = prune_baseline()
        return doc

    def test_gate_pass_and_fail_exit_codes(self):
        base = self.write("baseline.json", self.full_baseline())
        ok = self.write("ok.json", scheduler_current())
        code, out = self.run_main([ok, base])
        self.assertEqual(code, 0)
        self.assertIn("gate passed", out)
        bad = self.write("bad.json", scheduler_current(cont=1.0))
        code, out = self.run_main([bad, base])
        self.assertEqual(code, 1)
        self.assertIn("FAILED", out)

    def test_section_selects_kernel_gates(self):
        base = self.write("baseline.json", self.full_baseline())
        cur = self.write("kern.json", kernels_current())
        code, out = self.run_main([cur, base, "--section", "kernels"])
        self.assertEqual(code, 0)
        # the scheduler-only gates must not leak into the section run
        self.assertNotIn("speedup_x", out)
        bad = self.write("kern_bad.json", kernels_current(macko=1.0))
        code, _ = self.run_main([bad, base, "--section", "kernels"])
        self.assertEqual(code, 1)

    def test_section_selects_prune_gates(self):
        base = self.write("baseline.json", self.full_baseline())
        cur = self.write("prune.json", prune_current())
        code, out = self.run_main([cur, base, "--section", "prune"])
        self.assertEqual(code, 0)
        # scheduler- and kernels-only gates must not leak in
        self.assertNotIn("speedup_x", out)
        self.assertNotIn("tiled_untiled_ratio", out)
        bad = self.write("prune_bad.json", prune_current(ratio=0.5))
        code, _ = self.run_main([bad, base, "--section", "prune"])
        self.assertEqual(code, 1)

    def test_section_inherits_top_level_tolerance(self):
        doc = self.full_baseline()
        doc["tolerance"] = 0.5  # kernels section sets none of its own
        base = self.write("baseline.json", doc)
        # 40 * (1 - 0.5) = 20: a 21 tok/s macko squeaks by
        cur = self.write("kern.json", kernels_current(macko=21.0))
        code, _ = self.run_main([cur, base, "--section", "kernels"])
        self.assertEqual(code, 0)

    def test_missing_section_is_usage_error(self):
        base = self.write("baseline.json", scheduler_baseline())
        cur = self.write("cur.json", scheduler_current())
        code, _ = self.run_main([cur, base, "--section", "nope"])
        self.assertEqual(code, 2)

    def test_ratchet_stdout_roundtrips(self):
        base = self.write("baseline.json", self.full_baseline())
        cur = self.write("cur.json", scheduler_current())
        code, out = self.run_main([cur, base, "--ratchet"])
        self.assertEqual(code, 0)
        doc = json.loads(out)
        self.assertEqual(doc["continuous"]["tok_s"], 150.0)
        # untouched sections survive the ratchet
        self.assertEqual(doc["kernels"]["macko"]["tok_s"], 40.0)

    def test_ratchet_section_write_rewrites_file(self):
        base = self.write("baseline.json", self.full_baseline())
        cur = self.write("kern.json", kernels_current())
        code, _ = self.run_main(
            [cur, base, "--section", "kernels", "--ratchet", "--write"])
        self.assertEqual(code, 0)
        with open(base) as f:
            doc = json.load(f)
        self.assertEqual(doc["kernels"]["macko"]["tok_s"], 220.0)
        self.assertEqual(doc["kernels"]["min_tiled_untiled_ratio"], 0.95)
        # scheduler floors outside the section are untouched
        self.assertEqual(doc["continuous"]["tok_s"], 80.0)

    def test_diff_always_exits_zero_even_on_regression(self):
        # --diff is the non-blocking CI step: a stream that would fail
        # the gate still exits 0 and prints the drift table
        base = self.write("baseline.json", self.full_baseline())
        bad = self.write("bad.json", kernels_current(macko=1.0))
        code, _ = self.run_main([bad, base, "--section", "kernels"])
        self.assertEqual(code, 1)
        code, out = self.run_main(
            [bad, base, "--section", "kernels", "--diff"])
        self.assertEqual(code, 0)
        self.assertIn("below gate floor", out)
        self.assertIn("floor drift", out)
        self.assertNotIn("FAILED", out)

    def test_unreadable_input_is_error_not_crash(self):
        base = self.write("baseline.json", scheduler_baseline())
        code, _ = self.run_main(["/nonexistent.json", base])
        self.assertEqual(code, 2)


if __name__ == "__main__":
    unittest.main()

"""Tests for ci/lint_mirror.py against the shared fixture suite.

The fixtures in rust/tests/lint_fixtures/ are the contract between the
authoritative Rust linter (rust/src/lint, exercised by
rust/tests/lint_fixtures.rs) and this mirror: each rule class has a bad
snippet that must fire and a good snippet that must stay quiet, with
identical expected rules and line numbers on both sides. The suite also
runs the mirror over the real tree, mirroring the blocking `elsa-lint`
CI step.

Run: python3 -m unittest ci.test_lint_mirror  (or unittest discover ci)
"""

import os
import sys
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import lint_mirror  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "rust", "tests", "lint_fixtures")


def fixture(name):
    with open(os.path.join(FIXTURES, name), encoding="utf-8") as fh:
        return fh.read()


def lint(path, src):
    return lint_mirror.lint_source(path, src)


def rules(violations):
    return [rule for (_p, _l, rule, _m) in violations]


def lines(violations):
    return [line for (_p, line, _r, _m) in violations]


class HotFnTable:
    """Temporarily swap the mirror's hot-fn table for fixture runs."""

    def __init__(self, table):
        self.table = table

    def __enter__(self):
        self.saved = lint_mirror.HOT_FNS
        lint_mirror.HOT_FNS = self.table

    def __exit__(self, *exc):
        lint_mirror.HOT_FNS = self.saved


class TestSafetyRule(unittest.TestCase):
    def test_bad_fixture_fires_on_both_sites(self):
        v = lint("infer/fixture.rs", fixture("bad_unsafe.rs"))
        self.assertEqual(rules(v), ["safety", "safety"])
        self.assertEqual(lines(v), [3, 7])

    def test_good_fixture_is_quiet(self):
        v = lint("infer/fixture.rs", fixture("good_unsafe.rs"))
        self.assertEqual(v, [])

    def test_safety_tag_requires_a_reason(self):
        src = "// SAFETY:\nunsafe impl Send for X {}\n"
        self.assertEqual(rules(lint("infer/f.rs", src)), ["safety"])


class TestNondetRule(unittest.TestCase):
    def test_bad_fixture_fires_in_watched_module(self):
        v = lint("sparse/fixture.rs", fixture("bad_nondet.rs"))
        self.assertEqual(rules(v), ["nondet", "nondet"])
        self.assertEqual(lines(v), [5, 10])

    def test_same_source_outside_watched_modules_is_legal(self):
        v = lint("util/fixture.rs", fixture("bad_nondet.rs"))
        self.assertEqual(v, [])

    def test_good_fixture_is_quiet(self):
        v = lint("sparse/fixture.rs", fixture("good_nondet.rs"))
        self.assertEqual(v, [])


class TestAllocRule(unittest.TestCase):
    def test_bad_fixture_fires_only_in_listed_hot_fn(self):
        with HotFnTable((("sparse/fixture.rs", ("hot",)),)):
            v = lint("sparse/fixture.rs", fixture("bad_alloc.rs"))
        self.assertEqual(rules(v), ["alloc"])
        self.assertEqual(lines(v), [5])

    def test_good_fixture_is_quiet(self):
        with HotFnTable((("sparse/fixture.rs", ("hot",)),)):
            v = lint("sparse/fixture.rs", fixture("good_alloc.rs"))
        self.assertEqual(v, [])

    def test_stale_table_entry_is_a_config_error(self):
        with HotFnTable((("sparse/fixture.rs", ("decode",)),)):
            v = lint("sparse/fixture.rs", fixture("bad_alloc.rs"))
        self.assertEqual(rules(v), ["config"])


class TestWildcardRule(unittest.TestCase):
    def test_bad_fixture_fires_once(self):
        v = lint("infer/fixture.rs", fixture("bad_wildcard.rs"))
        self.assertEqual(rules(v), ["wildcard"])
        self.assertEqual(lines(v), [12])

    def test_good_fixture_is_quiet(self):
        v = lint("infer/fixture.rs", fixture("good_wildcard.rs"))
        self.assertEqual(v, [])


class TestLexer(unittest.TestCase):
    def test_blank_preserves_shape(self):
        src = 'let a = "unsafe"; // unsafe\nlet b = \'x\';\n'
        out = lint_mirror.blank(src)
        self.assertEqual(len(out), len(src))
        self.assertEqual(out.count("\n"), src.count("\n"))
        self.assertNotIn("unsafe", out)

    def test_blank_raw_strings(self):
        src = 'let s = r#"match _ => unsafe"#;\n'
        out = lint_mirror.blank(src)
        self.assertNotIn("unsafe", out)
        self.assertNotIn("match", out)

    def test_lifetimes_stay_code(self):
        out = lint_mirror.blank("fn f<'a>(x: &'a u32) -> &'a u32 { x }\n")
        self.assertIn("<'a>", out)


class TestRealTree(unittest.TestCase):
    def test_rust_src_is_clean(self):
        violations = lint_mirror.lint_tree(os.path.join(REPO, "rust", "src"))
        self.assertEqual(
            violations, [],
            "\n".join(f"{p}:{l}: [{r}] {m}"
                      for (p, l, r, m) in violations))

    def test_hot_fn_table_matches_the_tree(self):
        # every (file, fn) entry must resolve: a rename that bypasses
        # the table shows up here (and as a `config` violation above)
        root = os.path.join(REPO, "rust", "src")
        for path, fns in lint_mirror.HOT_FNS:
            with open(os.path.join(root, path), encoding="utf-8") as fh:
                code = lint_mirror.blank(fh.read())
            for name in fns:
                self.assertTrue(
                    lint_mirror.fn_extents(code, name),
                    f"{path}: hot fn `{name}` not found")


if __name__ == "__main__":
    unittest.main()

#!/usr/bin/env python3
"""Offline mirror of `elsa-lint` (rust/src/lint/mod.rs).

The Rust binary (`cargo run --bin elsa-lint`) is the authoritative
implementation and the blocking CI step. This mirror re-implements the
same four rules line-for-line so the invariants can also be checked
from environments without a Rust toolchain, and so the lint logic
itself has executable test coverage in `ci/test_lint_mirror.py`
(which runs the mirror over the real tree and over the shared fixture
suite in `rust/tests/lint_fixtures/`). If the two implementations ever
disagree on the fixtures, the fixture tests on both sides catch it.

Rules (see docs/ARCHITECTURE.md section 8 for the full table):
  R1 safety    every `unsafe` block/fn/impl is immediately preceded by
               a `// SAFETY:` comment with a non-empty argument
  R2 nondet    no nondeterminism sources in kernel/model modules
               (sparse/, model/, tensor/, pruners/) outside sites
               annotated `// TIMING-OK:` / `// DETERMINISM-OK: <why>`
  R3 alloc     no allocation calls inside the per-step decode hot path
               (a fixed table of file -> fn names) outside
               `// ALLOC-OK: <why>` sites
  R4 wildcard  no `_ =>` wildcard arm in any match whose arm patterns
               name WeightFmt/QuantMode/KernelPath/Backend variants

Usage: python3 ci/lint_mirror.py [root]   (root defaults to rust/src)
Exit status 0 when clean, 1 when violations are found.
"""

import os
import sys

SAFETY_TAG = "SAFETY:"
TIMING_TAG = "TIMING-OK:"
DETERMINISM_TAG = "DETERMINISM-OK:"
ALLOC_TAG = "ALLOC-OK:"

WATCHED_DIRS = ("sparse/", "model/", "tensor/", "pruners/")

NONDET_TOKENS = (
    "Instant::now",
    "SystemTime",
    "env::var",
    "thread::sleep",
    "RandomState",
    "HashMap",
)

ALLOC_TOKENS = (
    "Vec::new",
    "vec!",
    ".to_vec(",
    ".clone(",
    ".collect",
    "Box::new",
    "with_capacity",
    "String::new",
    "format!",
    ".to_string(",
    ".to_owned(",
)

EXHAUSTIVE_ENUMS = ("WeightFmt::", "QuantMode::", "KernelPath::", "Backend::")

# The per-step decode hot path: file (relative to the lint root) ->
# function names whose bodies must be allocation-free outside ALLOC-OK
# sites. Renaming or deleting a listed fn is itself a lint error so the
# table cannot silently go stale.
HOT_FNS = (
    ("sparse/mod.rs", ("matvec", "matvec_batch_into",
                       "matvec_batch_tiled_into", "axpy_lanes",
                       "transpose_batch_into")),
    ("sparse/tile.rs", ("exec_tiles", "matvec_batch_tiled",
                        "pool_matvec_batch_tiled", "pool_t_matmat",
                        "scatter_rows")),
    ("sparse/quantized.rs", ("matvec", "matvec_batch_into",
                             "matvec_batch_tiled_into", "exec_tiles")),
    ("sparse/nm.rs", ("matvec", "row_acc", "matvec_batch_into",
                      "matvec_batch_tiled_into", "exec_tiles")),
    ("infer/pool.rs", ("run", "drain", "worker_loop")),
    ("infer/mod.rs", ("decode_step_batch", "layer_qkv", "layer_ffn",
                      "attend_cached", "prefill_pass_multi")),
)


def blank(src):
    """Return src with comment and string/char-literal contents replaced
    by spaces (newlines preserved), so token scans see only code."""
    out = []
    b = src
    n = len(b)
    i = 0
    CODE, LINE, BLOCK, STR, RAWSTR, CH = range(6)
    st = CODE
    depth = 0  # block-comment nesting / raw-string hash count
    while i < n:
        c = b[i]
        nxt = b[i + 1] if i + 1 < n else ""
        if st == CODE:
            if c == "/" and nxt == "/":
                st = LINE
                out.append("  ")
                i += 2
            elif c == "/" and nxt == "*":
                st = BLOCK
                depth = 1
                out.append("  ")
                i += 2
            elif c == '"':
                st = STR
                out.append(" ")
                i += 1
            elif c in "rb":
                j = i + 1 if (c == "b" and nxt == "r") else i
                if b[j] == "r":
                    k = j + 1
                    hashes = 0
                    while k < n and b[k] == "#":
                        hashes += 1
                        k += 1
                    if k < n and b[k] == '"':
                        out.append(" " * (k + 1 - i))
                        i = k + 1
                        st = RAWSTR
                        depth = hashes
                        continue
                out.append(c)
                i += 1
            elif c == "'":
                is_char = nxt == "\\" or (i + 2 < n and b[i + 2] == "'")
                if is_char:
                    st = CH
                    out.append(" ")
                    i += 1
                else:  # lifetime
                    out.append(c)
                    i += 1
            else:
                out.append(c)
                i += 1
        elif st == LINE:
            if c == "\n":
                out.append("\n")
                st = CODE
            else:
                out.append(" ")
            i += 1
        elif st == BLOCK:
            if c == "*" and nxt == "/":
                out.append("  ")
                i += 2
                depth -= 1
                if depth == 0:
                    st = CODE
            elif c == "/" and nxt == "*":
                out.append("  ")
                i += 2
                depth += 1
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
        elif st == STR:
            if c == "\\" and i + 1 < n:
                out.append(" ")
                out.append("\n" if nxt == "\n" else " ")
                i += 2
            elif c == '"':
                out.append(" ")
                st = CODE
                i += 1
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
        elif st == RAWSTR:
            if c == '"':
                k = i + 1
                m = 0
                while m < depth and k < n and b[k] == "#":
                    m += 1
                    k += 1
                if m == depth:
                    out.append(" " * (k - i))
                    i = k
                    st = CODE
                    continue
            out.append("\n" if c == "\n" else " ")
            i += 1
        elif st == CH:
            if c == "\\" and i + 1 < n:
                out.append("  ")
                i += 2
            elif c == "'":
                out.append(" ")
                st = CODE
                i += 1
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
    return "".join(out)


def is_ident(c):
    return c.isalnum() or c == "_"


def find_word(line, word, start=0):
    """Index of `word` in line with non-identifier chars on both sides,
    or -1."""
    i = start
    while True:
        p = line.find(word, i)
        if p < 0:
            return -1
        before_ok = p == 0 or not is_ident(line[p - 1])
        after = p + len(word)
        after_ok = after >= len(line) or not is_ident(line[after])
        if before_ok and after_ok:
            return p
        i = p + 1


def line_has_tag(line, tags):
    for tag in tags:
        p = line.find(tag)
        if p >= 0 and line[p + len(tag):].strip():
            return True
    return False


def annotated(orig_lines, code_lines, idx, tags, skip_unsafe_impl=False):
    """True when line idx carries one of `tags` (with a non-empty
    reason) on the same line or in the immediately preceding block of
    comment/attribute lines. With skip_unsafe_impl, single-line
    `unsafe impl` items may sit between the flagged line and the
    comment so one SAFETY block covers a Send/Sync pair."""
    if line_has_tag(orig_lines[idx], tags):
        return True
    j = idx
    while j > 0:
        j -= 1
        t = orig_lines[j].lstrip()
        if t.startswith("//"):
            if line_has_tag(orig_lines[j], tags):
                return True
            continue
        if t.startswith("#[") or t.startswith("#!"):
            continue
        if skip_unsafe_impl and find_word(code_lines[j], "unsafe") >= 0 \
                and "impl" in code_lines[j]:
            continue
        break
    return False


def rule_safety(path, orig_lines, code_lines, out):
    for i, code in enumerate(code_lines):
        if find_word(code, "unsafe") < 0:
            continue
        is_impl = "impl" in code
        if not annotated(orig_lines, code_lines, i, (SAFETY_TAG,),
                         skip_unsafe_impl=is_impl):
            out.append((path, i + 1, "safety",
                        "`unsafe` without an immediately preceding "
                        "`// SAFETY:` comment"))


def rule_nondet(path, orig_lines, code_lines, out):
    if not path.startswith(WATCHED_DIRS):
        return
    for i, code in enumerate(code_lines):
        for tok in NONDET_TOKENS:
            if tok not in code:
                continue
            if not annotated(orig_lines, code_lines, i,
                             (TIMING_TAG, DETERMINISM_TAG)):
                out.append((path, i + 1, "nondet",
                            f"nondeterminism source `{tok}` in a "
                            "kernel/model module without a "
                            "TIMING-OK/DETERMINISM-OK annotation"))


def brace_depths(code):
    """Per-char brace depth: chars inside {...} sit one deeper; both
    braces of a pair report the outer depth."""
    depths = []
    d = 0
    for c in code:
        if c == "}":
            d -= 1
        depths.append(d)
        if c == "{":
            d += 1
    return depths


def fn_extents(code, name):
    """(body_start, body_end) char offsets for every `fn name` with a
    body; bodyless trait declarations are skipped."""
    extents = []
    depths = brace_depths(code)
    i = 0
    while True:
        p = find_word(code, "fn", i)
        if p < 0:
            break
        i = p + 2
        rest = code[p + 2:].lstrip()
        if not (rest.startswith(name)
                and (len(rest) == len(name)
                     or not is_ident(rest[len(name)]))):
            continue
        # scan to the body `{` (or a `;` for a bodyless declaration)
        paren = 0
        j = p
        while j < len(code):
            c = code[j]
            if c == "(":
                paren += 1
            elif c == ")":
                paren -= 1
            elif c == ";" and paren == 0:
                j = -1
                break
            elif c == "{" and paren == 0:
                break
            j += 1
        if j < 0 or j >= len(code):
            continue
        d = depths[j]
        k = j + 1
        while k < len(code) and not (code[k] == "}" and depths[k] == d):
            k += 1
        extents.append((j, k))
        i = k
    return extents


def rule_alloc(path, orig_lines, code_lines, code, out):
    fns = dict(HOT_FNS).get(path)
    if not fns:
        return
    line_of = offsets_to_lines(code)
    for name in fns:
        extents = fn_extents(code, name)
        if not extents:
            out.append((path, 1, "config",
                        f"hot-path fn `{name}` not found in {path} — "
                        "update the hot-path table in the linter"))
            continue
        for (start, end) in extents:
            first = line_of[start]
            last = line_of[end]
            for li in range(first, last + 1):
                cl = code_lines[li]
                for tok in ALLOC_TOKENS:
                    if tok not in cl:
                        continue
                    if not annotated(orig_lines, code_lines, li,
                                     (ALLOC_TAG,)):
                        out.append((path, li + 1, "alloc",
                                    f"allocation `{tok}` inside hot-path "
                                    f"fn `{name}` without an ALLOC-OK "
                                    "annotation"))


def offsets_to_lines(code):
    """char offset -> 0-based line index."""
    line_of = [0] * len(code)
    ln = 0
    for i, c in enumerate(code):
        line_of[i] = ln
        if c == "\n":
            ln += 1
    return line_of


def rule_wildcard(path, code_lines, code, out):
    depths = brace_depths(code)
    line_of = offsets_to_lines(code)
    i = 0
    while True:
        p = find_word(code, "match", i)
        if p < 0:
            break
        i = p + 5
        if p > 0 and code[:p].rstrip().endswith("."):
            continue  # method call, not the keyword
        # body `{` at relative paren/bracket depth 0
        paren = 0
        j = p + 5
        while j < len(code):
            c = code[j]
            if c in "([":
                paren += 1
            elif c in ")]":
                paren -= 1
            elif c == "{" and paren == 0:
                break
            elif c == ";" and paren == 0:
                j = -1
                break
            j += 1
        if j is None or j < 0 or j >= len(code):
            continue
        d = depths[j]
        k = j + 1
        while k < len(code) and not (code[k] == "}" and depths[k] == d):
            k += 1
        arm_sep = []  # offsets of `=>` directly inside the match braces
        m = j + 1
        while m + 1 < k:
            if code[m] == "=" and code[m + 1] == ">" and depths[m] == d + 1:
                arm_sep.append(m)
            m += 1
        arms = []
        for s in arm_sep:
            # pattern = text back to the previous arm-separating comma
            # (skipping commas nested in ()/[]) or the match `{`
            b = s - 1
            nest = 0
            while b > j:
                c = code[b]
                if c in ")]":
                    nest += 1
                elif c in "([":
                    nest -= 1
                elif c == "," and nest == 0 and depths[b] == d + 1:
                    break
                elif c in "{}" and depths[b] <= d:
                    break
                b -= 1
            pat = code[b + 1:s].strip().lstrip("|").strip()
            core = pat.split(" if ")[0].strip()
            arms.append((core, line_of[s]))
        if not any(any(e in core for e in EXHAUSTIVE_ENUMS)
                   for core, _ in arms):
            continue
        for core, ln in arms:
            if core == "_":
                out.append((path, ln + 1, "wildcard",
                            "`_ =>` wildcard arm in a match over "
                            "WeightFmt/QuantMode/KernelPath/Backend — "
                            "spell the variants so new formats fail "
                            "exhaustiveness"))


def lint_source(path, src):
    """Lint one file; `path` is relative to the lint root (used for the
    watched-module and hot-path tables)."""
    code = blank(src)
    orig_lines = src.split("\n")
    code_lines = code.split("\n")
    out = []
    rule_safety(path, orig_lines, code_lines, out)
    rule_nondet(path, orig_lines, code_lines, out)
    rule_alloc(path, orig_lines, code_lines, code, out)
    rule_wildcard(path, code_lines, code, out)
    return out


def lint_tree(root):
    out = []
    found = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for f in filenames:
            if f.endswith(".rs"):
                found.append(os.path.join(dirpath, f))
    for full in sorted(found):
        rel = os.path.relpath(full, root).replace(os.sep, "/")
        with open(full, encoding="utf-8") as fh:
            out.extend(lint_source(rel, fh.read()))
    return out


def main(argv):
    root = argv[1] if len(argv) > 1 else "rust/src"
    violations = lint_tree(root)
    for (path, line, rule, msg) in violations:
        print(f"{path}:{line}: [{rule}] {msg}", file=sys.stderr)
    if violations:
        print(f"lint mirror: {len(violations)} violation(s)",
              file=sys.stderr)
        return 1
    print(f"lint mirror: clean ({root})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

#!/usr/bin/env python3
"""Bench regression gate for the CI bench-smoke job.

Usage:
  compare_bench.py CURRENT.json BASELINE.json [--section NAME]
  compare_bench.py CURRENT.json BASELINE.json [--section NAME] --ratchet
                   [--write]
  compare_bench.py CURRENT.json BASELINE.json [--section NAME] --diff

Gating rules, applied against BASELINE (or BASELINE[NAME] when
--section NAME is given; a section inherits the top-level "tolerance"
unless it sets its own):

  * every baseline entry of the form {"<policy>": {"tok_s": <floor>}}
    requires CURRENT[<policy>]["tok_s"] >= (1 - tolerance) * floor; a
    gated policy missing from CURRENT fails the gate (a vanished bench
    is a regression, not a free pass);
  * "min_speedup_x", when present, requires
    CURRENT["speedup_x"] >= min_speedup_x;
  * every "min_<name>_ratio" knob requires
    CURRENT["<name>_ratio"] >= the floor (e.g. min_tiled_untiled_ratio
    gates tiled_untiled_ratio, min_pooled_serial_ratio gates
    pooled_serial_ratio, min_chunked_pertoken_ratio gates the
    chunked-vs-per-token prefill ratio chunked_pertoken_ratio); an
    absent metric counts as 0.0 and fails.

The tok_s rule covers the chunked-prefill cells too: a baseline entry
like {"macko_prefill": {"tok_s": <floor>}} floors the chunked prefill
rate the same way the decode policies are floored (extra keys in the
current cell, e.g. pertoken_tok_s, are informational and ignored by
the gate), and --ratchet updates its tok_s like any other policy.

Latency percentiles are reported for the record but never gated: on
the shared CI fleet they are far noisier than aggregate throughput.

--ratchet emits an updated baseline document (stdout by default,
rewritten in place with --write) whose tok_s floors are replaced by
the measured values in CURRENT. Run it on a downloaded BENCH_*
artifact to tighten the committed floors once a few runs establish
the fleet's spread. The tolerance and min_* knobs are policy, not
measurements — ratcheting never touches them.

--diff prints a floor-drift summary instead of gating: every floored
policy and ratio knob with its committed baseline, the measured value,
and the percentage drift, flagging entries sitting below the gate
floor or so far above the committed number that the floor has gone
stale (ratchet candidates). It always exits 0 — it is the non-blocking
companion the CI job runs for the log, never a gate.

Exit codes: 0 gate passed / ratchet emitted / diff printed, 1
regression, 2 usage or input error.
"""

import argparse
import copy
import json
import sys


def gated_policies(baseline):
    """Baseline keys that carry a tok_s floor (dict entries only)."""
    return [k for k, v in baseline.items()
            if isinstance(v, dict) and "tok_s" in v]


def gate(current, baseline, tolerance=None):
    """Apply the gating rules; return (report_lines, failures)."""
    if tolerance is None:
        tolerance = float(baseline.get("tolerance", 0.15))
    lines = []
    failures = []

    lines.append(f"{'metric':<14} {'baseline':>10} {'floor':>10} "
                 f"{'current':>10}  status")
    for policy in gated_policies(baseline):
        base = float(baseline[policy]["tok_s"])
        floor = base * (1.0 - tolerance)
        if policy not in current:
            # a gated policy vanishing from the bench output is itself
            # a regression, not a free pass
            lines.append(f"{policy:<14} {base:>10.1f} {floor:>10.1f} "
                         f"{'MISSING':>10}  REGRESSION")
            failures.append(f"{policy}: missing from bench output")
            continue
        got = float(current[policy]["tok_s"])
        ok = got >= floor
        lines.append(f"{policy:<14} {base:>10.1f} {floor:>10.1f} "
                     f"{got:>10.1f}  {'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(
                f"{policy}: {got:.1f} tok/s < floor {floor:.1f} "
                f"(baseline {base:.1f}, tolerance {tolerance:.0%})")

    if "min_speedup_x" in baseline:
        floor = float(baseline["min_speedup_x"])
        got = float(current.get("speedup_x", 0.0))
        ok = got >= floor
        lines.append(f"{'speedup_x':<14} {floor:>10.2f} {floor:>10.2f} "
                     f"{got:>10.2f}  {'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(
                f"continuous/static speedup {got:.2f}x < {floor:.2f}x")

    # generic ratio knobs: min_<name>_ratio gates CURRENT["<name>_ratio"]
    for knob in sorted(k for k in baseline
                       if k.startswith("min_") and k.endswith("_ratio")):
        metric = knob[len("min_"):]
        floor = float(baseline[knob])
        got = float(current.get(metric, 0.0))
        ok = got >= floor
        lines.append(f"{metric:<14} {floor:>10.2f} {floor:>10.2f} "
                     f"{got:>10.2f}  {'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(
                f"{metric} {got:.2f} < floor {floor:.2f}")

    for policy in gated_policies(baseline):
        p = current.get(policy)
        if isinstance(p, dict) and "p50_ms" in p:
            lines.append(
                f"  {policy} latency: p50 {p.get('p50_ms', 0):.2f} ms, "
                f"p95 {p.get('p95_ms', 0):.2f} ms (not gated)")

    return lines, failures


def diff(current, baseline, tolerance=None):
    """Floor-drift summary: baseline vs measured for every floored
    policy and ratio knob, with percentage drift. Purely informational
    — returns report lines, never failures."""
    if tolerance is None:
        tolerance = float(baseline.get("tolerance", 0.15))
    lines = [f"{'metric':<24} {'baseline':>10} {'current':>10} "
             f"{'drift':>8}"]
    stale, below = 0, 0
    for policy in gated_policies(baseline):
        base = float(baseline[policy]["tok_s"])
        cur = current.get(policy)
        if not (isinstance(cur, dict) and "tok_s" in cur):
            lines.append(f"{policy:<24} {base:>10.1f} {'missing':>10}")
            continue
        got = float(cur["tok_s"])
        drift = (got - base) / base * 100.0 if base else 0.0
        note = ""
        if got < base * (1.0 - tolerance):
            note = "  below gate floor"
            below += 1
        elif drift > 100.0:
            note = "  floor stale (ratchet candidate)"
            stale += 1
        lines.append(f"{policy:<24} {base:>10.1f} {got:>10.1f} "
                     f"{drift:>+7.1f}%{note}")
    for knob in sorted(k for k in baseline
                       if k.startswith("min_") and k.endswith("_ratio")):
        metric = knob[len("min_"):]
        floor = float(baseline[knob])
        if metric not in current:
            lines.append(f"{metric:<24} {floor:>10.2f} {'missing':>10}")
            continue
        got = float(current[metric])
        drift = (got - floor) / floor * 100.0 if floor else 0.0
        note = ""
        if got < floor:
            note = "  below gate floor"
            below += 1
        lines.append(f"{metric:<24} {floor:>10.2f} {got:>10.2f} "
                     f"{drift:>+7.1f}%{note}")
    lines.append(f"floor drift: {below} below gate floor, {stale} "
                 f"stale floor(s) worth ratcheting (informational "
                 f"only, never gated)")
    return lines


def ratchet(current, baseline):
    """Return a copy of `baseline` whose tok_s floors are replaced by
    the measured values in `current` (policies absent from `current`
    keep their old floor; tolerance/min_* knobs are left untouched)."""
    out = copy.deepcopy(baseline)
    for policy in gated_policies(baseline):
        cur = current.get(policy)
        if isinstance(cur, dict) and "tok_s" in cur:
            out[policy]["tok_s"] = round(float(cur["tok_s"]), 1)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="compare_bench.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("current", help="bench output JSON (BENCH_*.json)")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--section", default=None,
                    help="gate against BASELINE[SECTION] instead of the "
                         "top level")
    ap.add_argument("--ratchet", action="store_true",
                    help="emit an updated baseline from CURRENT instead "
                         "of gating")
    ap.add_argument("--write", action="store_true",
                    help="with --ratchet: rewrite BASELINE in place")
    ap.add_argument("--diff", action="store_true",
                    help="print a non-blocking floor-drift summary "
                         "(always exits 0)")
    args = ap.parse_args(argv)

    try:
        with open(args.current) as f:
            current = json.load(f)
        with open(args.baseline) as f:
            baseline_doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"compare_bench: {e}", file=sys.stderr)
        return 2

    section = baseline_doc
    if args.section is not None:
        section = baseline_doc.get(args.section)
        if not isinstance(section, dict):
            print(f"compare_bench: baseline has no section "
                  f"'{args.section}'", file=sys.stderr)
            return 2

    tolerance = float(section.get(
        "tolerance", baseline_doc.get("tolerance", 0.15)))

    if args.ratchet:
        new_section = ratchet(current, section)
        if args.section is not None:
            out_doc = dict(baseline_doc)
            out_doc[args.section] = new_section
        else:
            out_doc = new_section
        text = json.dumps(out_doc, indent=2) + "\n"
        if args.write:
            with open(args.baseline, "w") as f:
                f.write(text)
            print(f"ratcheted floors written to {args.baseline}")
        else:
            print(text, end="")
        return 0

    if args.diff:
        for line in diff(current, section, tolerance):
            print(line)
        return 0

    lines, failures = gate(current, section, tolerance)
    for line in lines:
        print(line)
    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

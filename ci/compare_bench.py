#!/usr/bin/env python3
"""Bench regression gate for the CI bench-smoke job.

Usage: compare_bench.py BENCH_scheduler.json ci/bench_baseline.json

Fails (exit 1) when any policy's throughput in the current bench run
drops below (1 - tolerance) of the committed baseline floor, or when the
continuous-vs-static speedup falls below the baseline's min_speedup_x
(continuous admission must keep beating static batching).

Latency percentiles are reported for the record but not gated: on the
shared CI fleet they are far noisier than aggregate throughput.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        current = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)

    tolerance = float(baseline.get("tolerance", 0.15))
    failures = []

    print(f"{'policy':<12} {'baseline':>10} {'floor':>10} "
          f"{'current':>10}  status")
    gated = [k for k, v in baseline.items()
             if isinstance(v, dict) and "tok_s" in v]
    for policy in gated:
        base = float(baseline[policy]["tok_s"])
        floor = base * (1.0 - tolerance)
        if policy not in current:
            # a gated policy vanishing from the bench output is itself
            # a regression, not a free pass
            print(f"{policy:<12} {base:>10.1f} {floor:>10.1f} "
                  f"{'MISSING':>10}  REGRESSION")
            failures.append(f"{policy}: missing from bench output")
            continue
        got = float(current[policy]["tok_s"])
        ok = got >= floor
        print(f"{policy:<12} {base:>10.1f} {floor:>10.1f} {got:>10.1f}  "
              f"{'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(
                f"{policy}: {got:.1f} tok/s < floor {floor:.1f} "
                f"(baseline {base:.1f}, tolerance {tolerance:.0%})")

    min_speedup = float(baseline.get("min_speedup_x", 1.0))
    speedup = float(current.get("speedup_x", 0.0))
    ok = speedup >= min_speedup
    print(f"{'speedup_x':<12} {min_speedup:>10.2f} {min_speedup:>10.2f} "
          f"{speedup:>10.2f}  {'ok' if ok else 'REGRESSION'}")
    if not ok:
        failures.append(
            f"continuous/static speedup {speedup:.2f}x < {min_speedup:.2f}x")

    for policy in ("static", "continuous"):
        if policy in current:
            p = current[policy]
            print(f"  {policy} latency: p50 {p.get('p50_ms', 0):.2f} ms, "
                  f"p95 {p.get('p95_ms', 0):.2f} ms (not gated)")

    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
